"""Diff a BENCH*.json artifact against its committed baseline.

CI's bench-smoke job used to be a crash gate only: a hierarchical perf
regression (say, cross-segment stealing silently disabled) sailed through
as long as the script exited 0.  This comparer makes regressions fail
loudly while staying robust to CI-runner speed variance:

* raw ``us_per_call`` timings are **never** compared — they measure the
  runner, not the code;
* boolean derived flags (``beats_seq=True`` …) must not flip to False;
* numeric derived *ratio* metrics (``*speedup*``, ``S'`` …) may degrade to
  ``RATIO_SLACK`` of the baseline before failing — relative metrics divide
  out the runner speed;
* hard floors in ``FLOORS`` encode acceptance gates that must hold on any
  machine (phase-1 cross-segment stealing win on the straggler-segment
  profile);
* every baseline row must still exist (a renamed/dropped benchmark is a
  silent coverage loss).

Usage:  python benchmarks/compare_baseline.py CURRENT.json BASELINE.json
Exit 0 on pass, 1 with a per-row diff report on fail.
"""

from __future__ import annotations

import json
import sys

RATIO_SLACK = 0.7   # ratio metrics may degrade to 70% of baseline
FLOORS = {
    # Tentpole acceptance: cross-segment stealing >= 1.3x faster phase-1
    # makespan than static segments on the straggler-segment profile.
    # CI runners are noisy, so the hard floor sits below 1.3; the committed
    # baseline value (compared with RATIO_SLACK) carries the real target.
    "phase1_speedup": 1.15,
    # Resident-runtime acceptance (bench_serve.py): shared pool >= 1.5x
    # per-call threads at 4 concurrent series, incremental extend >= 3x a
    # full recompute.  Floors again sit below the targets for runner noise;
    # the committed baselines carry the real ratios.
    "pool_speedup": 1.2,
    "extend_speedup": 2.0,
    # Device-resident hot path (bench_scan_kernels.py --kernels): the
    # single-pass decoupled-lookback kernel >= 1.5x the threaded
    # hierarchical backend on the cheap operator at n=4096, and a warm
    # compile-cache start >= 2x faster to first results than a cold one.
    # Committed baseline ratios are hand-clamped well below measured values
    # (300x+ / 70x on the dev container) so RATIO_SLACK stays meaningful
    # on slow shared runners; these floors are the true acceptance bars.
    "device_speedup": 1.5,
    "warm_speedup": 2.0,
    # Serving acceptance (bench_slo.py): with a straggler tenant saturating
    # the front end, the priority/round-robin policy's interactive p99 must
    # beat FIFO's by >= 2x.  This floor IS the ISSUE 8 acceptance bar; the
    # committed baseline ratio is hand-clamped to 3.0 (measured 5.5-7.6x)
    # so RATIO_SLACK keeps margin on slow runners.
    "p99_speedup": 2.0,
    # Sharded multi-device acceptance (bench_sharded.py): one 4096-element
    # series split across 8 virtual devices must beat the single-device
    # vector backend by >= 1.5x wall — the blocked reduce-then-scan work
    # advantage, since every virtual device shares the same cores.  This
    # floor IS the acceptance bar; the committed baseline ratio is
    # hand-clamped to 1.6 (measured ~2.2x) so RATIO_SLACK keeps margin.
    # The round-efficiency gates (phase2_rounds == ceil(log2 p), <= the
    # hierarchical baseline, == the simulator's prediction) ride along as
    # boolean flags that must not flip.
    "sharded_speedup_8dev": 1.5,
}
RATIO_KEYS = ("speedup", "S'", "S_vs_static")


def parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        if v in ("True", "False"):
            out[k] = v == "True"
            continue
        num = v[:-1] if v.endswith("x") else v  # "1.74x" -> 1.74
        try:
            out[k] = float(num)
        except ValueError:
            out[k] = v
    return out


def load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: parse_derived(r.get("derived", "")) for r in doc["rows"]}


def compare(cur_path: str, base_path: str) -> list:
    cur = load_rows(cur_path)
    base = load_rows(base_path)
    failures = []
    for name, bd in base.items():
        cd = cur.get(name)
        if cd is None:
            failures.append(f"{name}: row missing from {cur_path}")
            continue
        for k, bv in bd.items():
            cv = cd.get(k)
            if isinstance(bv, bool):
                # "meets_*" flags restate an acceptance threshold on the
                # underlying ratio (e.g. meets_1p3x over phase1_speedup);
                # gating on them would re-raise the bar past the FLOORS /
                # RATIO_SLACK noise allowances, so only the ratio gates.
                if k.startswith("meets_"):
                    continue
                if bv and cv is not True:
                    failures.append(f"{name}: {k} flipped True -> {cv}")
            elif isinstance(bv, float) and any(t in k for t in RATIO_KEYS):
                if not isinstance(cv, float):
                    failures.append(f"{name}: {k} missing (baseline {bv})")
                elif cv < bv * RATIO_SLACK:
                    failures.append(
                        f"{name}: {k} {cv:.2f} < {RATIO_SLACK} x "
                        f"baseline {bv:.2f}"
                    )
        for k, floor in FLOORS.items():
            cv = cd.get(k)
            if isinstance(cv, float) and cv < floor:
                failures.append(f"{name}: {k} {cv:.2f} below floor {floor}")
    return failures


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    cur_path, base_path = sys.argv[1], sys.argv[2]
    failures = compare(cur_path, base_path)
    if failures:
        print(f"BENCH REGRESSION: {cur_path} vs {base_path}")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    print(f"bench diff OK: {cur_path} vs {base_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
