"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only microbench,...]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    bench_hierarchical,
    bench_microbench,
    bench_operator_cost,
    bench_registration_e2e,
    bench_scan_kernels,
    bench_serve,
    bench_sharded,
    bench_slo,
    bench_strong_scaling,
    bench_weak_scaling,
    bench_work_energy,
    roofline,
)

SUITES = {
    "microbench": bench_microbench,          # paper Fig. 8
    "strong_scaling": bench_strong_scaling,  # paper Table 3 / Fig. 1 & 9
    "hierarchical": bench_hierarchical,      # paper Table 4
    "work_energy": bench_work_energy,        # paper Table 5
    "weak_scaling": bench_weak_scaling,      # paper Fig. 10
    "operator_cost": bench_operator_cost,    # paper Fig. 5
    "registration_e2e": bench_registration_e2e,  # paper Figs. 1/9 (real time)
    "scan_kernels": bench_scan_kernels,      # in-model scan paths (real time)
    "serve": bench_serve,                    # resident runtime / sessions
    "slo": bench_slo,                        # serving tail latency (ISSUE 8)
    "sharded": bench_sharded,                # multi-device strong scaling
    "roofline": roofline,                    # dry-run roofline table
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        mod = SUITES[name]
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception:  # noqa: BLE001 — isolate suite failures
            traceback.print_exc()
            failed.append(name)
            continue
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.2f},{derived}")
        print(f"# suite {name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
