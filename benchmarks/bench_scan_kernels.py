"""Wall-clock benchmarks of the in-model scan paths on this container's CPU:
chunked SSD scan (reduce-then-scan) vs naive sequential recurrence, and the
circuit choice for the inter-chunk phase.  Real timings, not simulation."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(f, *args, reps=3):
    y = f(*args)
    jax.block_until_ready(y)
    t0 = time.time()
    for _ in range(reps):
        y = f(*args)
    jax.block_until_ready(y)
    return (time.time() - t0) / reps


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    b, h, l, dk, dv = 2, 4, 2048, 64, 64
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, h, l, dk)) * 0.3
    k = jax.random.normal(ks[1], (b, h, l, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, h, l, dv)) * 0.5
    la = -jax.nn.softplus(jax.random.normal(ks[3], (b, h, l)))

    seq = jax.jit(jax.vmap(jax.vmap(ref.ssm_scan_reference)))
    t_seq = _time(seq, q, k, v, la)
    rows.append(("ssd_sequential_recurrence", t_seq * 1e6,
                 f"tok_per_s={b * l / t_seq:.0f}"))
    for chunk in [64, 128, 256]:
        f = jax.jit(lambda q, k, v, la, c=chunk: ops.ssd_scan(
            q, k, v, la, chunk=c, backend="xla"))
        t = _time(f, q, k, v, la)
        rows.append((f"ssd_chunked_c{chunk}", t * 1e6,
                     f"speedup_vs_seq={t_seq / t:.1f}x"))
    for alg in ["sequential", "dissemination", "ladner_fischer", "brent_kung"]:
        f = jax.jit(lambda q, k, v, la, a=alg: ops.ssd_scan(
            q, k, v, la, chunk=128, backend="xla", scan_algorithm=a))
        t = _time(f, q, k, v, la)
        rows.append((f"ssd_interchunk_{alg}", t * 1e6, "chunk=128"))
    # attention: blockwise-causal vs full-mask (memory-light vs naive)
    d = 64
    q4 = jax.random.normal(ks[0], (1, 4, 2048, d)) * 0.4
    f_block = jax.jit(lambda q, k, v: ops.attention(q, k, v, causal=True,
                                                    backend="xla"))
    t = _time(f_block, q4, q4, q4)
    rows.append(("attention_blockwise_2k", t * 1e6, ""))
    return rows
