"""Wall-clock benchmarks of the in-model scan paths on this container's CPU:
chunked SSD scan (reduce-then-scan) vs naive sequential recurrence, the
circuit choice for the inter-chunk phase, and the unified scan engine
(plan-cached dispatch vs the seed-style per-call circuit re-trace).
Real timings, not simulation."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.circuits import get_circuit
from repro.core.engine import scan as engine_scan
from repro.core.engine.backends import exec_vector
from repro.core.engine.plan import get_plan, lower
from repro.kernels import ops, ref


def _time(f, *args, reps=3):
    y = f(*args)
    jax.block_until_ready(y)
    t0 = time.time()
    for _ in range(reps):
        y = f(*args)
    jax.block_until_ready(y)
    return (time.time() - t0) / reps


def run(*, smoke: bool = False):
    """``smoke=True`` shrinks shapes/reps to CI size (~tens of seconds):
    the rows exist to catch crashes and keep the perf trajectory files
    populated, not to resolve small regressions on shared runners."""
    rows = []
    key = jax.random.PRNGKey(0)
    b, h, l, dk, dv = 2, 4, (512 if smoke else 2048), 64, 64
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, h, l, dk)) * 0.3
    k = jax.random.normal(ks[1], (b, h, l, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, h, l, dv)) * 0.5
    la = -jax.nn.softplus(jax.random.normal(ks[3], (b, h, l)))

    seq = jax.jit(jax.vmap(jax.vmap(ref.ssm_scan_reference)))
    t_seq = _time(seq, q, k, v, la)
    rows.append(("ssd_sequential_recurrence", t_seq * 1e6,
                 f"tok_per_s={b * l / t_seq:.0f}"))
    for chunk in [64, 128] if smoke else [64, 128, 256]:
        f = jax.jit(lambda q, k, v, la, c=chunk: ops.ssd_scan(
            q, k, v, la, chunk=c, backend="xla"))
        t = _time(f, q, k, v, la)
        rows.append((f"ssd_chunked_c{chunk}", t * 1e6,
                     f"speedup_vs_seq={t_seq / t:.1f}x"))
    for alg in ["sequential", "dissemination", "ladner_fischer", "brent_kung"]:
        f = jax.jit(lambda q, k, v, la, a=alg: ops.ssd_scan(
            q, k, v, la, chunk=128, backend="xla", scan_algorithm=a))
        t = _time(f, q, k, v, la)
        rows.append((f"ssd_interchunk_{alg}", t * 1e6, "chunk=128"))
    # attention: blockwise-causal vs full-mask (memory-light vs naive)
    d = 64
    la_len = 512 if smoke else 2048
    q4 = jax.random.normal(ks[0], (1, 4, la_len, d)) * 0.4
    f_block = jax.jit(lambda q, k, v: ops.attention(q, k, v, causal=True,
                                                    backend="xla"))
    t = _time(f_block, q4, q4, q4)
    rows.append((f"attention_blockwise_l{la_len}", t * 1e6, ""))
    rows.extend(run_engine(smoke=smoke))
    return rows


def run_engine(*, smoke: bool = False):
    """Unified scan engine: plan-cached dispatch vs seed-style re-trace.

    The acceptance bar for the engine refactor: for the add-operator
    microbenchmark, dispatching through the cached plan must not be slower
    than the seed ``jax_exec`` path, which re-ran the circuit trace loop
    (identity resolution, gather/scatter index-list building) on every call.
    """
    rows = []
    add = lambda a, b: a + b
    n = 1024 if smoke else 4096
    x = jnp.arange(1.0, n + 1.0)
    circuit = get_circuit("ladner_fischer", n)

    def seed_style(x):
        # The pre-engine jax_exec: symbolic trace + index building per call.
        plan = lower(circuit)
        y, _ = exec_vector(add, plan, x)
        return y

    def engine_cached(x):
        return engine_scan(add, x, backend="vector", algorithm="ladner_fischer")

    get_plan("ladner_fischer", n)  # warm the plan cache
    reps = 3 if smoke else 5
    t_seed = _time(seed_style, x, reps=reps)
    t_eng = _time(engine_cached, x, reps=reps)
    rows.append((f"scan_add_seed_retrace_n{n}", t_seed * 1e6, ""))
    rows.append((f"scan_add_engine_cached_n{n}", t_eng * 1e6,
                 f"speedup_vs_retrace={t_seed / t_eng:.2f}x"))
    t_auto = _time(lambda x: engine_scan(add, x), x, reps=reps)
    rows.append((f"scan_add_engine_dispatch_n{n}", t_auto * 1e6,
                 "cost-model dispatch"))
    t_pl = _time(
        lambda x: engine_scan(add, x, backend="pallas", num_blocks=8),
        x, reps=3,
    )
    rows.append((f"scan_add_pallas_tiles_n{n}", t_pl * 1e6,
                 "tile-scan kernels (interpret on CPU)"))
    t_hier = _time(
        lambda x: engine_scan(add, x, backend="hierarchical", num_segments=8),
        x, reps=3,
    )
    rows.append((f"scan_add_hierarchical_s8_n{n}", t_hier * 1e6,
                 "vectorized two-level reduce-then-scan"))
    return rows


def run_kernels(*, smoke: bool = False):
    """Device-resident hot path: single-pass decoupled-lookback kernel vs
    the threaded element-domain hierarchical backend vs the sequential
    chain, plus the persistent compile cache's warm-vs-cold latency.

    The acceptance gate (compare_baseline FLOORS): the device path must be
    >= 1.5x the threaded hierarchical backend on the cheap operator at
    n=4096 — the regime where per-element thread dispatch overhead, not
    operator cost, dominates — and a warm compile-cache start must reach
    first results >= 2x faster than a cold one.
    """
    rows = []
    key = jax.random.PRNGKey(0)
    reps = 2 if smoke else 5

    def chain(op, x):
        acc = x[0]
        for i in range(1, x.shape[0]):
            acc = op(acc, x[i])
        return acc

    cases = [
        # (kind, n, element shape, operator)
        ("cheap_add_d8", 256, (8,), lambda a, b: a + b),
        ("cheap_add_d8", 4096, (8,), lambda a, b: a + b),
        ("medium_matmul16", 256, (16, 16), lambda a, b: jnp.matmul(b, a)),
        ("medium_matmul16", 4096, (16, 16), lambda a, b: jnp.matmul(b, a)),
    ]
    for kind, n, shape, op in cases:
        x = jax.random.normal(key, (n,) + shape) * 0.1
        if "matmul" in kind:
            # Keep products bounded so the chain stays finite.
            x = x + jnp.eye(shape[0]) * 0.9
        f_dec = jax.jit(lambda x, op=op: engine_scan(
            op, x, backend="decoupled"))
        t_dec = _time(f_dec, x, reps=reps)
        xs = [x[i] for i in range(n)]
        t_hier = _time(
            lambda xs, op=op: engine_scan(
                op, xs, backend="hierarchical", num_segments=8, num_threads=2
            ),
            xs, reps=1 if smoke else 2,
        )
        t_seq = _time(chain, op, x, reps=1 if smoke else 2)
        derived = (
            f"speedup_vs_seq={t_seq / t_dec:.2f}x;"
            f"hier_us={t_hier * 1e6:.0f}"
        )
        if kind == "cheap_add_d8" and n == 4096:
            derived = (
                f"device_speedup={t_hier / t_dec:.2f}x;"
                f"speedup_vs_seq={t_seq / t_dec:.2f}x"
            )
        rows.append((f"dscan_{kind}_n{n}_decoupled", t_dec * 1e6, derived))
    rows.append(_compile_cache_row())
    return rows


def _compile_cache_row():
    """Warm-vs-cold first-result latency through the AOT executable cache.

    Uses a private CompileCache instance and a registration config no other
    code path compiles (max_iters=77), so the cold leg really pays the XLA
    compile whichever rows or processes ran before it.
    """
    import time as _t

    from repro.core.registration import RegistrationConfig, register_pair
    from repro.runtime.compile_cache import CompileCache

    cache = CompileCache()
    cfg = RegistrationConfig(max_iters=77)
    frames = jax.random.normal(jax.random.PRNGKey(1), (9, 32, 32))
    refs, tmps = frames[:-1], frames[1:]
    ckey = ("pair_vmap", register_pair, 8, (32, 32), "float32", cfg)
    build = lambda: jax.vmap(lambda r, t: register_pair(r, t, None, cfg))

    def first_result():
        fn = cache.get_compiled(ckey, build, lower_args=(refs, tmps))
        jax.block_until_ready(fn(refs, tmps))

    t0 = _t.perf_counter()
    first_result()
    t_cold = _t.perf_counter() - t0
    t0 = _t.perf_counter()
    first_result()
    t_warm = _t.perf_counter() - t0
    stats = cache.stats()
    return (
        "compile_cache_warm_vs_cold", t_warm * 1e6,
        f"warm_speedup={t_cold / t_warm:.2f}x;"
        f"cache_hits={stats['hits']:.0f};cache_misses={stats['misses']:.0f}",
    )


def main():
    try:
        from _cli import bench_cli          # script: python benchmarks/...
    except ImportError:
        from ._cli import bench_cli         # package: benchmarks.run

    def extra(ap):
        ap.add_argument(
            "--kernels", action="store_true",
            help="device-resident rows only (decoupled kernel + compile "
                 "cache) -> BENCH_kernels_ci.json",
        )

    def dispatch(*, smoke=False, kernels=False):
        return run_kernels(smoke=smoke) if kernels else run(smoke=smoke)

    bench_cli("scan_kernels", dispatch, extra_args=extra)


if __name__ == "__main__":
    main()
