"""Shared CLI + artifact writer for smoke-capable benchmark scripts.

One schema for every BENCH*.json the CI bench-smoke job uploads — change it
here and all artifacts stay comparable.
"""

from __future__ import annotations

import argparse
import json
from typing import Callable, Optional


def bench_cli(
    benchmark: str,
    run: Callable[..., list],
    *,
    extra_args: Optional[Callable[[argparse.ArgumentParser], None]] = None,
) -> None:
    """Parse --smoke/--json (plus ``extra_args``), run, print CSV rows, and
    optionally write the JSON artifact.  Extra parsed options are forwarded
    to ``run`` as keyword arguments."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes and reps")
    ap.add_argument("--json", default=None,
                    help="also write rows as a JSON artifact")
    if extra_args is not None:
        extra_args(ap)
    args = ap.parse_args()
    kwargs = {k: v for k, v in vars(args).items() if k != "json"}
    rows = run(**kwargs)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {"benchmark": benchmark, "smoke": args.smoke,
                 "rows": [
                     {"name": name, "us_per_call": us, "derived": derived}
                     for name, us, derived in rows
                 ]},
                f, indent=2,
            )
        print(f"# wrote {args.json}")
