"""Paper Fig. 5: the real registration operator's cost distribution and the
load imbalance of static segmentation — measured on the actual JAX operator
(iteration counts + wall time on synthetic lattice frames)."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.registration import RegistrationConfig, register_pair
from repro.data.images import make_series


def run():
    rows = []
    frames, _ = make_series(jax.random.PRNGKey(42), 40, size=96, noise=0.2)
    cfg = RegistrationConfig()
    iters, times = [], []
    # Warm the jit once.
    register_pair(frames[0], frames[1], None, cfg)
    for i in range(39):
        t0 = time.time()
        res = register_pair(frames[i], frames[i + 1], None, cfg)
        jax.block_until_ready(res.deformation)
        times.append(time.time() - t0)
        iters.append(int(res.iterations))
    iters = np.array(iters)
    times = np.array(times)
    rows.append(("fig5a_operator_mean", float(times.mean() * 1e6),
                 f"iters_mean={iters.mean():.0f};iters_max={iters.max()};"
                 f"iters_min={iters.min()}"))
    rows.append(("fig5a_operator_p95", float(np.percentile(times, 95) * 1e6),
                 f"cv={times.std() / times.mean():.3f}"))
    # Fig 5b: imbalance of static segmentation vs segment size (iteration
    # counts as the cost proxy, as in the paper's analysis).
    for seg in [4, 8, 16]:
        nseg = len(iters) // seg
        loads = iters[: nseg * seg].reshape(nseg, seg).sum(1).astype(float)
        imb = (loads.max() - loads.mean()) / loads.mean()
        rows.append((f"fig5b_imbalance_seg{seg}", 0.0, f"imbalance={imb:.3f}"))
    return rows
