"""End-to-end registration-as-scan benchmark (paper §5, Figs. 1/9).

Two parts:

1. **Controlled cost profiles** — the scan operator is a rigid-transform
   composition plus a *synthetic* per-element delay (the paper's mock
   operators): uniform, linear ramp and single-straggler distributions over
   a 256-frame series.  This isolates executor behaviour from minimiser
   noise and is the acceptance gate: the hierarchical backend must beat
   both the naive sequential loop and the best flat engine backend on the
   single-straggler profile.  Delays sleep, so thread overlap is real even
   on the 2-core CI runner.

2. **Real registration** — ``repro.register_series`` on a synthetic
   drifting lattice series vs the naive sequential registration loop, with
   per-stage timings (time-to-solution, paper Fig. 1).  On a 2-core host
   the compute-bound operator limits the achievable overlap; the controlled
   profiles above carry the scaling story.

CLI:  PYTHONPATH=src python benchmarks/bench_registration_e2e.py
          [--smoke] [--json out.json] [--frames N]
"""

from __future__ import annotations

import math
import time


BASE_DELAY = 0.002     # seconds per operator application (mock operator)
# Single-straggler cost multiplier.  Capped relative to N: a straggler that
# alone outweighs the rest of the series bounds every executor by its double
# application in reduce-then-scan (phase 1 + phase 3), which says nothing
# about scheduling quality.  n/5 keeps the straggler ~20% of total work.
STRAGGLER = lambda n: min(50.0, n / 5.0)
# Straggler-*segment* multiplier: every element of one whole segment costs
# 16x base, making that segment ~3.4x the mean segment cost (with 4
# segments, one segment can be at most 4x the mean) — the paper's Fig. 5a
# registration-cost tail concentrated in one contiguous stretch, which
# within-segment stealing cannot fix.
SEG_STRAGGLER = 16.0
SEGMENTS, SEG_THREADS = 4, 2
FLAT_THREADS = SEGMENTS * SEG_THREADS


# --- the mock scan element: rigid transform + index pair, no JAX overhead
# (math-module compose keeps the operator GIL-free outside the sleep).


def _rigid_compose(a, b):
    ang = a[0] + b[0]
    c, s = math.cos(b[0]), math.sin(b[0])
    return (ang, c * a[1] - s * a[2] + b[1], s * a[1] + c * a[2] + b[2])


def _elements(n, delays=None):
    """Mock RegElements: (transform, i, k, delay).  The delay rides on the
    element; a combine costs the *dearer operand's* registration time (the
    hard frame pair dominates whichever side it is folded from, §2.3.3) and
    a combined partial carries the base rate (a fresh pair registration),
    not its constituents' — indexing delays by wire position would bill the
    straggler to every phase that touches its segment total."""
    if delays is None:
        delays = [0.0] * n
    return [
        ((0.001 * (i % 7), 0.3 * ((i % 5) - 2), 0.2 * ((i % 3) - 1)),
         i, i + 1, delays[i])
        for i in range(n)
    ]


def _delays(profile, n, base=BASE_DELAY):
    if profile == "uniform":
        return [base] * n
    if profile == "ramp":
        return [base * (0.2 + 1.6 * i / max(n - 1, 1)) for i in range(n)]
    if profile == "straggler":
        d = [base] * n
        d[n // 2] = base * STRAGGLER(n)
        return d
    if profile == "straggler_seg":
        d = [base] * n
        for i in range(n // SEGMENTS, 2 * n // SEGMENTS):
            d[i] = base * SEG_STRAGGLER  # segment 1 of SEGMENTS is slow
        return d
    raise ValueError(profile)


def _make_op(base=BASE_DELAY):
    def op(a, b):
        d = max(a[3], b[3])
        if d:
            time.sleep(d)
        assert a[2] == b[1], "non-adjacent combine"
        return (_rigid_compose(a[0], b[0]), a[1], b[2], base)

    return op


def _seq_scan(op, xs):
    out = [xs[0]]
    for x in xs[1:]:
        out.append(op(out[-1], x))
    return out


def _check(ys, ref):
    assert len(ys) == len(ref)
    for y, r in zip(ys, ref):
        assert y[1] == r[1] and y[2] == r[2]
        assert all(abs(u - v) < 1e-9 for u, v in zip(y[0], r[0]))


def _profile_rows(n):
    """Part 1: executor comparison under controlled cost distributions."""
    from repro.core.engine import scan as engine_scan

    rows = []
    ref = _seq_scan(_make_op(0.0), _elements(n))
    for profile in ["uniform", "ramp", "straggler"]:
        elems = _elements(n, _delays(profile, n))
        op = _make_op()

        t0 = time.perf_counter()
        _check(_seq_scan(op, list(elems)), ref)
        t_seq = time.perf_counter() - t0
        rows.append((f"e2e_{profile}_sequential_n{n}", t_seq * 1e6, ""))

        flat_times = {}
        for alg in ["dissemination", "ladner_fischer"]:
            t0 = time.perf_counter()
            _check(
                engine_scan(op, list(elems), backend="element", algorithm=alg),
                ref,
            )
            flat_times[alg] = time.perf_counter() - t0
            rows.append((f"e2e_{profile}_flat_{alg}_n{n}",
                         flat_times[alg] * 1e6, "serial flat circuit"))
        t_flat = min(flat_times.values())

        t0 = time.perf_counter()
        _check(
            engine_scan(op, list(elems), backend="worksteal",
                        num_threads=FLAT_THREADS),
            ref,
        )
        t_ws = time.perf_counter() - t0
        rows.append((f"e2e_{profile}_worksteal_t{FLAT_THREADS}_n{n}",
                     t_ws * 1e6, "single-level stealing"))

        t0 = time.perf_counter()
        _check(
            engine_scan(op, list(elems), backend="hierarchical",
                        num_segments=SEGMENTS, num_threads=SEG_THREADS),
            ref,
        )
        t_h = time.perf_counter() - t0
        rows.append((
            f"e2e_{profile}_hierarchical_s{SEGMENTS}x{SEG_THREADS}_n{n}",
            t_h * 1e6,
            f"speedup_vs_seq={t_seq / t_h:.2f}x;"
            f"speedup_vs_best_flat={t_flat / t_h:.2f}x;"
            f"beats_seq={t_h < t_seq};beats_flat={t_h < t_flat}",
        ))
    return rows


def _cross_steal_rows(n):
    """Tentpole acceptance gate: on the straggler-*segment* profile,
    hierarchical with cross-segment stealing vs the static-segment
    hierarchical (PR-2 behaviour).  Phase-1 makespan is the paper's
    headline number — one slow segment bounds it exactly like the static
    baseline until neighbours can steal across the boundary gaps."""
    from repro.core.engine import hierarchical
    from repro.core.engine import scan as engine_scan

    ref = _seq_scan(_make_op(0.0), _elements(n))
    elems = _elements(n, _delays("straggler_seg", n))
    res = {}
    for cross in [False, True]:
        op = _make_op()
        t0 = time.perf_counter()
        _check(
            engine_scan(op, list(elems), backend="hierarchical",
                        num_segments=SEGMENTS, num_threads=SEG_THREADS,
                        cross_steal=cross),
            ref,
        )
        dt = time.perf_counter() - t0
        st = hierarchical.last_stats
        res[cross] = (dt, st.phase_seconds["reduce"], st)
    dt_s, p1_s, _ = res[False]
    dt_c, p1_c, st_c = res[True]
    tag = f"s{SEGMENTS}x{SEG_THREADS}_n{n}"
    return [
        (f"e2e_stragglerseg_hier_static_{tag}", dt_s * 1e6,
         f"phase1_s={p1_s:.3f}"),
        (f"e2e_stragglerseg_hier_cross_{tag}", dt_c * 1e6,
         f"phase1_s={p1_c:.3f};phase1_speedup={p1_s / p1_c:.2f};"
         f"total_speedup={dt_s / dt_c:.2f};"
         f"inter_segment_steals={st_c.total_inter_segment_steals()};"
         f"meets_1p3x={p1_s / p1_c >= 1.3}"),
    ]


def _curve_rows(n):
    """Time-to-solution vs parallelism on the straggler profile (Fig. 9)."""
    from repro.core.engine import scan as engine_scan

    rows = []
    elems = _elements(n, _delays("straggler", n))
    for s, t in [(1, 1), (2, 2), (4, 2), (4, 4)]:
        op = _make_op()
        t0 = time.perf_counter()
        if s * t == 1:
            _seq_scan(op, list(elems))
        else:
            engine_scan(op, list(elems), backend="hierarchical",
                        num_segments=s, num_threads=t)
        dt = time.perf_counter() - t0
        rows.append((f"e2e_curve_straggler_p{s * t}_n{n}", dt * 1e6,
                     f"segments={s};threads={t}"))
    return rows


def _real_rows(n_frames):
    """Part 2: the actual registration pipeline vs the sequential loop."""
    import jax
    import numpy as np

    import repro
    from repro.core.registration import SeriesRegistrar
    from repro.data.images import make_series

    rows = []
    frames, true = make_series(jax.random.PRNGKey(0), n_frames,
                               size=96, noise=0.15)

    reg = SeriesRegistrar(frames)
    t0 = time.perf_counter()
    elems = reg.preprocess_vmapped()
    seq = reg.sequential(list(elems))
    t_seq = time.perf_counter() - t0
    rows.append((f"e2e_real_sequential_f{n_frames}", t_seq * 1e6,
                 f"op_calls={reg.op_calls}"))

    res = repro.register_series(
        frames,
        repro.RegisterSeriesConfig(backend="hierarchical", num_segments=2,
                                   num_threads=2,
                                   telemetry_name="bench_e2e_real"),
    )
    t_pipe = sum(res.timings.values())
    err = float(np.abs(
        np.asarray(res.deformations["shift"])[1:]
        - np.asarray(true["shift"][1:])
    ).max())
    agree = max(
        float(np.abs(np.asarray(a.deformation["shift"])
                     - np.asarray(b.deformation["shift"])).max())
        for a, b in zip(seq, res.elements)
    )
    stages = ";".join(f"{k}={v:.3f}s" for k, v in res.timings.items())
    rows.append((f"e2e_real_pipeline_f{n_frames}", t_pipe * 1e6,
                 f"{stages};err_px={err:.3f};vs_seq_px={agree:.3f}"))
    return rows


def run(*, smoke: bool = False, frames: int | None = None):
    n = 64 if smoke else 256
    rows = _profile_rows(n)
    rows += _cross_steal_rows(n)
    rows += _curve_rows(n)
    rows += _real_rows(frames if frames is not None else (8 if smoke else 16))
    return rows


def main():
    try:
        from _cli import bench_cli          # script: python benchmarks/...
    except ImportError:
        from ._cli import bench_cli         # package: benchmarks.run

    bench_cli(
        "registration_e2e", run,
        extra_args=lambda ap: ap.add_argument(
            "--frames", type=int, default=None,
            help="frames for the real-registration section",
        ),
    )


if __name__ == "__main__":
    main()
