"""Paper Table 5: work (exact operator applications) and energy of full
registration, distributed vs work-stealing, vs the serial baseline."""

from __future__ import annotations

from repro.core.simulator import (
    registration_like_costs,
    simulate_distributed_scan,
)

N = 4096
CORES = [64, 128, 256, 512, 1024]


def run():
    rows = []
    costs = registration_like_costs(N)
    pre = registration_like_costs(N, seed=77)
    serial_work = (N - 1) + N  # scan ops + preprocessing (paper: 4096+4095)
    serial_busy = costs.sum() + pre.sum()
    serial_energy = serial_busy * 280.0  # busy watts only, one core
    for alg in ["dissemination", "ladner_fischer"]:
        for steal in [False, True]:
            tag = "steal" if steal else "static"
            for cores in CORES:
                threads = 12
                ranks = cores // threads
                n_use = N - N % ranks
                r = simulate_distributed_scan(
                    costs[:n_use], ranks=ranks, threads=threads,
                    algorithm=alg, stealing=steal,
                    preprocess_costs=pre[:n_use],
                )
                rows.append((
                    f"table5_{alg}_{tag}_{cores}",
                    r.makespan * 1e6,
                    f"work={r.work};work_x={r.work / serial_work:.2f};"
                    f"energy_MJ={r.energy / 1e6:.3f};"
                    f"energy_x={r.energy / serial_energy:.2f}",
                ))
    return rows
